"""Reproduce the paper's evaluation (Tables IV & V) on the simulated
16-server x 4-V100 / 10GbE cluster.

    PYTHONPATH=src python examples/schedule_cluster.py [--full] [--seed 0]

--full uses the exact paper workload (160 jobs over 20 min); the default
is a scaled trace that finishes in ~1 min on CPU.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import paper_trace, simulate


def fmt(res):
    return (
        f"util={res.gpu_util:6.1%}  avgJCT={res.avg_jct():8.1f}s  "
        f"median={res.median_jct():7.1f}s  p95={res.p95_jct():8.1f}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    jobs = (
        paper_trace(seed=args.seed)
        if args.full
        else paper_trace(seed=args.seed, n_jobs=64, min_iters=200, max_iters=1200)
    )
    print(f"workload: {len(jobs)} jobs "
          f"({sum(j.n_gpus for j in jobs)} GPU-slots demanded, 64 GPUs)")

    print("\n== Table IV: placement algorithms (with Ada-SRSF) ==")
    for placement in ("rand", "ff", "ls", "lwf"):
        t0 = time.time()
        res = simulate(jobs, placement=placement, comm="ada")
        name = "LWF-1" if placement == "lwf" else placement.upper()
        print(f"  {name:6s} {fmt(res)}   [{time.time()-t0:.0f}s sim]")

    print("\n== Table V: communication scheduling (with LWF-1) ==")
    for comm in ("srsf1", "srsf2", "srsf3", "ada", "kway3"):
        t0 = time.time()
        res = simulate(jobs, placement="lwf", comm=comm)
        name = {"ada": "Ada-SRSF", "kway3": "KWay-3 (ours)"}.get(comm, comm.upper())
        print(f"  {name:14s} {fmt(res)}   [{time.time()-t0:.0f}s sim]")


if __name__ == "__main__":
    main()
