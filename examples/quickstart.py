"""Quickstart: train a small llama-family model end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--d-model 320]

Uses the same driver the cluster launcher uses (repro.launch.train):
synthetic Zipf data pipeline -> jitted train step (AdamW, grad clip) ->
checkpointing.  With the defaults this is a ~27M-param model; pass
--d-model 512 --layers 12 for a ~100M-param run (a few hundred steps is
~30 min on one CPU core; on a real accelerator mesh the same code path
shards via --mesh-data/--mesh-model).
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=320)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("llama3.2-1b", reduced=True),
        name=f"quickstart-{args.d_model}d{args.layers}L",
        d_model=args.d_model,
        n_layers=args.layers,
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128),
        d_ff=args.d_model * 4,
        vocab_size=8192,
    )
    losses = train(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 2, 1),
        log_every=10,
    )
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"[quickstart] loss {losses[0]:.3f} -> {losses[-1]:.3f} OK")


if __name__ == "__main__":
    main()
