"""Batched serving: prefill a batch of prompts and decode with the KV/SSM
cache, for an attention arch and an (attention-free) SSM arch.

    PYTHONPATH=src python examples/serve_batch.py [--gen 32]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    args = ap.parse_args()
    for arch in ("llama3.2-1b", "mamba2-130m"):
        cfg = get_config(arch, reduced=True)
        res = serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
        print(
            f"{cfg.name:24s} prefill={res['prefill_tok_per_s']:8.0f} tok/s  "
            f"decode={res['decode_tok_per_s']:7.1f} tok/s  "
            f"sample={res['generated'][0][:8].tolist()}"
        )


if __name__ == "__main__":
    main()
