"""Multi-job training under Ada-SRSF: three real JAX training jobs
(different architectures) profiled, placed with LWF-1, their all-reduces
gated by AdaDUAL, and a slice of each job's real training executed.

    PYTHONPATH=src python examples/multi_job_training.py [--policy ada|srsf1]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.multi_job import JobRequest, run_multi_job


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="ada")
    ap.add_argument("--fabric", default="10gbe", choices=["10gbe", "tpu-dcn"])
    ap.add_argument("--execute-steps", type=int, default=6)
    args = ap.parse_args()

    requests = [
        JobRequest("llama3.2-1b", n_gpus=8, iterations=400, arrival=0.0),
        JobRequest("mamba2-130m", n_gpus=4, iterations=600, arrival=1.0),
        JobRequest("olmoe-1b-7b", n_gpus=8, iterations=300, arrival=2.0),
        JobRequest("gemma-7b", n_gpus=2, iterations=500, arrival=3.0),
    ]
    out = run_multi_job(
        requests,
        policy=args.policy,
        fabric=args.fabric,
        execute_steps=args.execute_steps,
    )
    res = out["schedule"]
    print(f"policy={res.policy_name} placement={res.placement_name} fabric={args.fabric}")
    for jid in out["order"]:
        prof = out["profiles"][jid]
        ls = out["losses"][jid]
        print(
            f"  J{jid} {prof.name:14s} t_iter={prof.t_iter_compute*1e3:7.1f}ms "
            f"msg={prof.size_bytes/1e6:7.1f}MB virtJCT={res.jct[jid]:8.1f}s "
            f"loss {ls[0]:.3f}->{ls[-1]:.3f}"
        )
    print(f"avg virtual JCT: {res.avg_jct():.1f}s   cluster util: {res.gpu_util:.1%}")


if __name__ == "__main__":
    main()
