"""Scenario-engine walkthrough: pick scenarios, run the policy matrix on the
exact event simulator, cross-check one on the fluid (JAX) backend.

    PYTHONPATH=src python examples/scenario_sweep.py
"""

from repro.scenarios import (
    describe,
    get_scenario,
    run_scenario_fluid,
    scenario_names,
    summarize,
    sweep,
)


def main() -> None:
    print("Registered scenarios:")
    for name in scenario_names():
        print(f"  {name:22s} {describe(name)}")

    # -- one cell by hand ---------------------------------------------------
    scn = get_scenario("adversarial_allbig", seed=1, n_jobs=8, base_iters=120)
    print(
        f"\n{scn.name}: {scn.n_jobs} jobs on "
        f"{scn.n_servers}x{scn.gpus_per_server} GPUs"
    )

    # -- the matrix: AdaDUAL vs the SRSF(n) baselines on two scenarios ------
    records = sweep(
        ["smoke", "adversarial_allbig"],
        comms=("ada", "srsf1", "srsf2"),
        seeds=(0, 1),
        overrides={},
    )
    print("\nscenario x policy (event backend, 2 seeds):")
    for key, agg in summarize(records).items():
        print(
            f"  {key:45s} avg_jct={agg['avg_jct']:8.1f}  "
            f"makespan={agg['makespan']:8.1f}  util={agg['gpu_util']:.3f}"
        )

    # -- the same smoke workload through the fluid backend ------------------
    fl = run_scenario_fluid(get_scenario("smoke"), comm="ada", dt=0.02)
    jcts = fl["jct"][fl["finished"]]
    print(
        f"\nfluid backend on smoke: {int(fl['finished'].sum())}/6 finished, "
        f"avg JCT {float(jcts.mean()):.2f}s (event reference ~7.5s; gap = "
        f"documented gang-placement + fixed-dt approximation)"
    )


if __name__ == "__main__":
    main()
