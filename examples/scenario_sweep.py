"""Scenario-engine walkthrough: pick scenarios, run the policy matrix on the
exact event simulator, cross-check one on the fluid (JAX) backend.

    PYTHONPATH=src python examples/scenario_sweep.py
"""

from repro.scenarios import (
    describe,
    get_scenario,
    run_scenario_fluid,
    scenario_names,
    summarize,
    sweep,
    sweep_ci,
)


def main() -> None:
    print("Registered scenarios:")
    for name in scenario_names():
        print(f"  {name:22s} {describe(name)}")

    # -- one cell by hand ---------------------------------------------------
    scn = get_scenario("adversarial_allbig", seed=1, n_jobs=8, base_iters=120)
    print(
        f"\n{scn.name}: {scn.n_jobs} jobs on "
        f"{scn.n_servers}x{scn.gpus_per_server} GPUs"
    )

    # -- the matrix: AdaDUAL vs the SRSF(n) baselines on two scenarios ------
    records = sweep(
        ["smoke", "adversarial_allbig"],
        comms=("ada", "srsf1", "srsf2"),
        seeds=(0, 1),
        overrides={},
    )
    print("\nscenario x policy (event backend, 2 seeds):")
    for key, agg in summarize(records).items():
        print(
            f"  {key:45s} avg_jct={agg['avg_jct']:8.1f}  "
            f"makespan={agg['makespan']:8.1f}  util={agg['gpu_util']:.3f}"
        )

    # -- the same smoke workload through the fluid backend ------------------
    fl = run_scenario_fluid(get_scenario("smoke"), comm="ada", dt=0.02)
    jcts = fl["jct"][fl["finished"]]
    print(
        f"\nfluid backend on smoke: {int(fl['finished'].sum())}/6 finished, "
        f"avg JCT {float(jcts.mean()):.2f}s (event reference ~7.5s; gap = "
        f"documented gang-placement + fixed-dt approximation)"
    )

    # -- Monte-Carlo confidence intervals: every seed of a cell in ONE
    # vmapped device launch (padded batch), mean +/- std per cell ----------
    cis = sweep_ci(
        ["contended_residue"],
        comms=("ada", "srsf2"),
        seeds=(0, 1, 2),
        backend="fluid",
        dt=0.05,
    )
    print("\nfluid Monte-Carlo (3 seeds, one vmapped batch per cell):")
    for c in cis:
        print(
            f"  {c.scenario}/{c.comm:6s} avg JCT "
            f"{c.avg_jct_mean:6.1f} +/- {c.avg_jct_std:5.1f} s "
            f"({c.n_seeds} seeds, finished {c.finished_frac:.0%})"
        )

    # -- network-fabric topology: rack-aware vs topology-blind placement ----
    # rack_locality puts rack-sized jobs behind 6x-oversubscribed uplinks;
    # lwf_rack (event) / rack_pack (fluid gang mode) keep them inside one
    # rack, plain LWF splits them across racks and pays the oversub rate.
    from repro.scenarios import run_scenario_event

    rack = get_scenario("rack_locality", seed=1)
    blind = run_scenario_event(rack, comm="ada", placement="lwf")
    aware = run_scenario_event(rack, comm="ada", placement="lwf_rack")
    print(
        f"\nrack_locality (2-server racks, 6x oversub uplinks): makespan "
        f"LWF={blind.makespan:.0f}s vs LWF_RACK={aware.makespan:.0f}s "
        f"({blind.makespan / aware.makespan:.1f}x from locality alone)"
    )


if __name__ == "__main__":
    main()
